"""Elastic fleet serving under session churn: ElasticFleet vs looped dict.

The steady-state fleet benchmark (bench_fleet.py) measures a fixed
population.  Real deployments are elastic — electrode streams connect,
drop, and reconnect continuously — so this module drives an
:class:`~repro.serve.lifecycle.ElasticFleet` with a SEEDED Poisson churn
trace (arrivals and departures drawn per round, chunk payloads included)
and reports what serving looks like while the slot map is in motion:

  churn.S{s}.p50 / .p99        per-decision push latency distribution
                               across churn rounds (pooled over iters)
  churn.S{s}.baseline_loop     dict-of-SeizureSession running the SAME
  churn.S{s}.fleet             trace (identical admissions, evictions
                               and payloads, replayed from the seed)
  churn.S{s}.speedup           fleet/baseline sessions-per-second ratio
                               under churn — the row the CI gate reads
  churn.S{s}.retention.speedup churn vs steady-state sessions/s in the
                               same process (how much throughput the
                               admission/eviction machinery costs)
  churn.norecompile            status: a full churn trace after warmup
                               compiles ZERO XLA programs (admit/evict
                               reuse slots without recompiling)
  churn.recovery               status: save -> churn -> kill (new fleet
                               from_checkpoint) -> replay is bit-exact
                               with the uninterrupted run's decisions

Methodology matches bench_fleet.py: min-over-iters statistic (shared-box
scheduler noise only ever adds time), explicit ``jax.block_until_ready``
on the fleet's raw rounds, and the trace covers admission + eviction +
push cost end to end — the whole point is that lifecycle ops ride inside
the serving loop.  Between timing iters the fleet is drained (all
sessions evicted) so every iter replays the trace from the same empty
slot map.

BENCH_TINY=1 (CI smoke) shrinks to S in {4, 8} on a small geometry.
"""

from __future__ import annotations

import os
import tempfile
import time

# multiple CPU "devices" let the elastic fleet spread tiles across cores;
# only effective when this module is the first jax-backend user in the
# process (see bench_fleet.py for why run.py does not force this globally)
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.cpu_count() or 1}"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny
from repro.analysis.guards import GuardViolation, no_recompiles
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.serve.engine import SeizureSession
from repro.serve.lifecycle import ElasticFleet

PIDS = ("p0", "p1")


def _config() -> tuple[HDCConfig, tuple[int, ...], int, int]:
    """(cfg, capacities, churn rounds per trace, timing iters)."""
    if tiny():
        cfg = HDCConfig(dim=256, segments=8, channels=16, window=64,
                        temporal_threshold=8)
        return cfg, (4, 8), 10, 2
    return HDCConfig(), (8, 64), 24, 3


def _trained(cfg: HDCConfig, seed: int) -> HDCPipeline:
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, cfg.codes, (1, 4 * cfg.window, cfg.channels), np.uint8))
    labels = np.asarray(rng.integers(0, 2, (1, 4), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    return HDCPipeline.init(jax.random.PRNGKey(42 + seed), cfg).train_one_shot(
        codes, jnp.asarray(labels))


def _pid(tid: int) -> str:
    return PIDS[tid % len(PIDS)]


def _trace(seed: int, rounds: int, capacity: int, cfg: HDCConfig
           ) -> list[tuple[list[int], list[int], dict[int, np.ndarray]]]:
    """Seeded Poisson churn trace: per round ``(arrivals, departures,
    {tid: (window, channels) chunk})`` — both executors replay it verbatim,
    so their work (and their decisions) is identical by construction.
    Occupancy is capped at ``capacity`` and floored at 1 live stream."""
    rng = np.random.default_rng(seed)
    lam = max(1.0, capacity / 8.0)
    live: list[int] = []
    next_tid = 0
    ops = []
    for r in range(rounds):
        n_arr = int(rng.poisson(lam))
        if r == 0:  # start the trace half-full so round 0 already serves
            n_arr = max(n_arr, capacity // 2, 1)
        arrivals = []
        for _ in range(n_arr):
            if len(live) < capacity:
                arrivals.append(next_tid)
                live.append(next_tid)
                next_tid += 1
        n_dep = min(int(rng.poisson(lam)), len(live) - 1)
        departures = ([int(t) for t in
                       rng.choice(live, size=n_dep, replace=False)]
                      if n_dep > 0 else [])
        for t in departures:
            live.remove(t)
        chunks = {t: rng.integers(0, cfg.codes, (cfg.window, cfg.channels),
                                  np.uint8) for t in live}
        ops.append((arrivals, departures, chunks))
    return ops


def _run_fleet(fleet: ElasticFleet, ops) -> list[tuple[float, int]]:
    """Replay the trace on the fleet; returns per-round ``(push seconds,
    sessions pushed)`` samples.  Caller drains the fleet afterwards."""
    tid_sid: dict[int, int] = {}
    lat = []
    for arrivals, departures, chunks in ops:
        for t in arrivals:
            tid_sid[t] = fleet.admit(_pid(t))
        if departures:
            fleet.evict([tid_sid.pop(t) for t in departures],
                        with_state=False)
        if chunks:
            t0 = time.perf_counter()
            rounds, _ = fleet.push_sessions_raw(
                {tid_sid[t]: c for t, c in chunks.items()})
            jax.block_until_ready([r.tiles for r in rounds])
            lat.append((time.perf_counter() - t0, len(chunks)))
    fleet.evict(sorted(tid_sid.values()), with_state=False)
    return lat


def _run_baseline(pipes: dict[str, HDCPipeline], ops
                  ) -> list[tuple[float, int]]:
    """The pre-elastic serving shape on the same trace: a dict of
    SeizureSession objects, one jit dispatch per live stream per round."""
    sessions: dict[int, SeizureSession] = {}
    lat = []
    for arrivals, departures, chunks in ops:
        for t in arrivals:
            sessions[t] = SeizureSession(pipes[_pid(t)])
        for t in departures:
            del sessions[t]
        if chunks:
            t0 = time.perf_counter()
            for t, c in chunks.items():
                sessions[t].push(c)  # decisions are host arrays already
            lat.append((time.perf_counter() - t0, len(chunks)))
    return lat


def _time_trace(run_once, iters: int) -> tuple[float, list[tuple[float, int]]]:
    """(min total trace seconds over iters, pooled per-round samples)."""
    best, pooled = float("inf"), []
    for _ in range(iters):
        t0 = time.perf_counter()
        lat = run_once()
        best = min(best, time.perf_counter() - t0)
        pooled.extend(lat)
    return best, pooled


def _norecompile_row(fleet: ElasticFleet, ops) -> dict:
    """Run one full churn trace inside ``no_recompiles()``: after warmup,
    admissions and evictions must reuse slots without any XLA compile."""
    n_ops = sum(len(a) + len(d) + bool(c) for a, d, c in ops)
    try:
        with no_recompiles():
            _run_fleet(fleet, ops)
        derived = (f"ok (0 compiles over {n_ops} lifecycle ops, "
                   f"{len(ops)} churn rounds)")
    except GuardViolation as e:
        derived = f"FAILED: {e}"
    return {"name": "churn.norecompile", "us_per_call": "", "derived": derived}


def _recovery_row(fleet: ElasticFleet, pipes, cfg: HDCConfig,
                  capacity: int) -> dict:
    """Checkpoint, keep serving, then prove a restarted fleet replays the
    post-checkpoint event suffix to bit-exact decisions."""
    rng = np.random.default_rng(7)
    sids = [fleet.admit(_pid(i)) for i in range(max(2, capacity // 2))]
    # settle mid-window so the checkpoint carries partial accumulator state
    fleet.push_sessions({s: rng.integers(
        0, cfg.codes, (cfg.window // 2, cfg.channels), np.uint8)
        for s in sids})
    with tempfile.TemporaryDirectory() as root:
        fleet.save(root)
        cursor = fleet.op_id
        # post-checkpoint churn the restarted worker must replay
        live_decisions = []
        extra = fleet.admit(_pid(len(sids)))
        for _ in range(3):
            chunks = {s: rng.integers(0, cfg.codes, (cfg.window, cfg.channels),
                                      np.uint8) for s in [*sids, extra]}
            live_decisions.append(fleet.push_sessions(chunks))
        fleet.evict([sids[0]], with_state=False)
        events = fleet.events_since(cursor)

        restarted = ElasticFleet.from_checkpoint(
            pipes, root, tile=fleet.capacity, max_tiles=1,
            buckets=(cfg.window, cfg.window // 2))
        replayed = restarted.replay(events)
    fleet.evict(sorted(fleet.sessions), with_state=False)

    pushes = [r for r in replayed.values() if isinstance(r, dict)
              and all(isinstance(v, list) for v in r.values())]
    compared = 0
    for live, redo in zip(live_decisions, pushes):
        for sid, decs in live.items():
            for a, b in zip(decs, redo[sid]):
                if (a.frame_index != b.frame_index
                        or a.prediction != b.prediction
                        or not np.array_equal(a.scores, b.scores)):
                    return {"name": "churn.recovery", "us_per_call": "",
                            "derived": f"FAILED: sid {sid} frame "
                                       f"{a.frame_index} diverged after "
                                       "restore+replay"}
                compared += 1
    if len(pushes) != len(live_decisions) or compared == 0:
        return {"name": "churn.recovery", "us_per_call": "",
                "derived": f"FAILED: replay returned {len(pushes)} push "
                           f"results for {len(live_decisions)} live pushes "
                           f"({compared} decisions compared)"}
    return {"name": "churn.recovery", "us_per_call": "",
            "derived": (f"ok ({len(events)} ops replayed after restart, "
                        f"{compared} decisions bit-exact)")}


def run() -> list[dict]:
    cfg, s_list, rounds, iters = _config()
    pipes = {p: _trained(cfg, i) for i, p in enumerate(PIDS)}
    rows = [{
        "name": "churn.devices",
        "us_per_call": "",
        "derived": (f"n={len(jax.devices())} (elastic tiles round-robin "
                    "across local devices)"),
    }]
    for s in s_list:
        ops = _trace(seed=s, rounds=rounds, capacity=s, cfg=cfg)
        n_rounds = sum(1 for _, _, c in ops if c)
        n_decisions = sum(len(c) for _, _, c in ops)

        _run_baseline(pipes, ops)  # warm the shared per-session jits
        t_base, _ = _time_trace(lambda: _run_baseline(pipes, ops), iters)

        fleet = ElasticFleet(pipes, tile=s, max_tiles=1,
                             queue_limit=8, log_rounds=4 * rounds + 16,
                             buckets=(cfg.window, cfg.window // 2))
        fleet.warmup()
        t_fleet, pooled = _time_trace(lambda: _run_fleet(fleet, ops), iters)

        # steady-state control: same process, slot map at rest
        steady_sids = [fleet.admit(_pid(i)) for i in range(s)]
        steady = {sid: ops[-1][2][next(iter(ops[-1][2]))]
                  for sid in steady_sids}

        def push_steady():
            raw, _ = fleet.push_sessions_raw(steady)
            jax.block_until_ready([r.tiles for r in raw])

        push_steady()  # settle into pure steady state before timing
        t_steady = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            push_steady()
            t_steady = min(t_steady, time.perf_counter() - t0)
        fleet.evict(steady_sids, with_state=False)

        per_dec = np.array([dt * 1e6 / n for dt, n in pooled])
        p50, p99 = np.percentile(per_dec, 50), np.percentile(per_dec, 99)
        for name, val in (("p50", p50), ("p99", p99)):
            rows.append({
                "name": f"churn.S{s}.{name}",
                "us_per_call": f"{val:.0f}",
                "derived": (f"per-decision push latency under Poisson churn "
                            f"({n_rounds} rounds, {n_decisions} decisions, "
                            f"{iters} iters pooled)"),
            })
        for name, t in (("baseline_loop", t_base), ("fleet", t_fleet)):
            rows.append({
                "name": f"churn.S{s}.{name}",
                "us_per_call": f"{t * 1e6:.0f}",
                "derived": (f"sessions/s={n_decisions / t:.1f}"
                            f";us/decision={t * 1e6 / n_decisions:.1f}"
                            f";trace={len(ops)} rounds"),
            })
        rows.append({
            "name": f"churn.S{s}.speedup",
            "us_per_call": "",
            "derived": (f"{t_base / t_fleet:.2f}x sessions/s vs looped "
                        f"SeizureSession dict under identical churn trace"),
        })
        churn_us = t_fleet * 1e6 / n_decisions
        steady_us = t_steady * 1e6 / s
        rows.append({
            "name": f"churn.S{s}.retention.speedup",
            "us_per_call": "",
            "derived": (f"{steady_us / churn_us:.2f}x churn vs steady-state "
                        f"sessions/s retained (same process, same capacity)"),
        })
        if s == s_list[-1]:
            rows.append(_norecompile_row(fleet, ops))
            rows.append(_recovery_row(fleet, pipes, cfg, s))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
