"""Online continual learning: one-shot vs iteratively retrained pipelines.

The deployment scenario the online subsystem targets (Pale et al.,
arXiv:2201.09759): a patient's seizure morphology drifts record-to-record
(discharge frequency rises, recruitment spreads), so a one-shot AM trained on
the first recorded seizure transfers poorly to later ones.  Three arms per
synthetic drifting patient:

* ``one_shot``  — paper baseline: train on record 0 only.
* ``iterative`` — ``fit_iterative`` on record 0 (batch-iterative epochs).
* ``adapted``   — ``fit_iterative`` on record 0, then ONLINE adaptation
                  (``SeizureSession.adapt`` true-label feedback per frame)
                  across records 1-2 — the continual-learning path.

All arms are evaluated on the held-out final record with the (fixed) k-of-m
post-processed detection metrics: detection accuracy, clean-detection
accuracy (detected AND no false alarm), mean detection delay, false-alarm
rate.  The summary row counts patients where the adapted arm improves
detection delay or (clean) accuracy over one-shot.

A second section measures fleet-scale adaptation throughput: one jitted
``StreamingFleet.adapt`` step for S concurrent sessions vs the per-session
loop.

BENCH_TINY=1 (CI smoke) shrinks to 4 patients on short records.
"""

from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny
from repro.core import metrics
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg
from repro.serve.engine import SeizureSession
from repro.serve.fleet import StreamingFleet

FIT_EPOCHS = 10
FIT_MARGIN = 1.0   # batch retraining also updates low-margin frames
ADAPT_MARGIN = 0.0  # streaming feedback updates only on errors


def _config() -> tuple[HDCConfig, int, dict, int]:
    if tiny():
        cfg = HDCConfig(dim=256, segments=8, window=128)
        return cfg, 4, dict(pre_s=12.0, ictal_s=16.0, post_s=6.0), 8
    return HDCConfig(dim=256, segments=8), 6, {}, 256


def _drifting_patient(pid: int, cfg: HDCConfig, rec_kw: dict,
                      n_records: int = 4):
    """Records with drifting morphology: frequency rises and recruitment
    spreads from seizure to seizure (the continual-learning headroom)."""
    rng = np.random.default_rng(9000 + pid)
    base = float(rng.uniform(18.0, 30.0))
    part = float(rng.uniform(0.4, 0.6))
    return [
        ieeg.make_record(rng, seed_freq=base * (1.0 + 0.12 * i),
                         participation_frac=min(part * (1.0 + 0.3 * i), 0.9),
                         **rec_kw)
        for i in range(n_records)
    ]


def _evaluate(pipe: HDCPipeline, records, cfg: HDCConfig) -> dict:
    res = []
    for rec in records:
        _, preds = pipe.infer(jnp.asarray(rec.codes[None]))
        res.append(metrics.detection_metrics(
            np.asarray(preds[0]), ieeg.onset_frame(rec, cfg.window),
            frame_seconds=cfg.window / ieeg.FS))
    agg = metrics.aggregate(res)
    agg["clean_accuracy"] = float(
        np.mean([r.detected and not r.false_alarm for r in res]))
    return agg


def _adapt_over(pipe: HDCPipeline, records, cfg: HDCConfig) -> HDCPipeline:
    """Stream records through a SeizureSession with true-label feedback."""
    sess = SeizureSession(pipe)
    for rec in records:
        labels = ieeg.frame_labels(rec, cfg.window)
        f = 0
        for start in range(0, len(labels) * cfg.window, cfg.window):
            for _ in sess.push(rec.codes[start:start + cfg.window]):
                sess.adapt(int(labels[f]), margin=ADAPT_MARGIN)
                f += 1
    return replace(pipe, class_hvs=sess.class_hvs, am_state=sess.am_state)


def _fmt(agg: dict) -> str:
    return (f"acc={agg['detection_accuracy']:.2f}"
            f";clean_acc={agg['clean_accuracy']:.2f}"
            f";delay_s={agg['mean_delay_s']:.2f}"
            f";fa={agg['false_alarm_rate']:.2f}")


def _improved(after: dict, before: dict) -> bool:
    """Detection delay or (clean) accuracy improved (acceptance criterion)."""
    if (after["detection_accuracy"] > before["detection_accuracy"]
            or after["clean_accuracy"] > before["clean_accuracy"]):
        return True
    if (after["detection_accuracy"] < before["detection_accuracy"]
            or after["clean_accuracy"] < before["clean_accuracy"]):
        return False
    if after["detection_accuracy"] == 0.0:
        return False  # both arms detect nothing: nothing improved
    return (np.isnan(before["mean_delay_s"])
            or after["mean_delay_s"] < before["mean_delay_s"])


def _fleet_rows(cfg: HDCConfig, pipe: HDCPipeline, s: int) -> list[dict]:
    rng = np.random.default_rng(1)
    fleet = StreamingFleet({"p": pipe}, ["p"] * s, buckets=(cfg.window,))
    chunks = [rng.integers(0, cfg.codes, (cfg.window, cfg.channels), np.uint8)
              for _ in range(s)]
    labels = rng.integers(0, cfg.n_classes, s)
    fleet.push(chunks)
    fleet.adapt(labels)  # warmup / compile
    iters = 3
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fleet.push(chunks)
        applied = fleet.adapt(labels)
        applied.sum()  # consume
        times.append(time.perf_counter() - t0)
    t = sorted(times)[iters // 2]
    return [{
        "name": f"online.fleet.S{s}.push_adapt",
        "us_per_call": f"{t * 1e6:.0f}",
        "derived": (f"sessions/s={s / t:.1f}"
                    f";adapts/s={s / t:.1f}"
                    f";us/session={t * 1e6 / s:.1f}"),
    }]


def run() -> list[dict]:
    cfg, n_patients, rec_kw, fleet_s = _config()
    rows = []
    wins = 0
    delays = {"one_shot": [], "iterative": [], "adapted": []}
    last_pipe = None
    for pid in range(n_patients):
        records = _drifting_patient(pid, cfg, rec_kw)
        rec0 = records[0]
        codes = jnp.asarray(rec0.codes[None])
        labels = jnp.asarray(ieeg.frame_labels(rec0, cfg.window)[None])
        pipe = HDCPipeline.init(jax.random.PRNGKey(pid), cfg)
        pipe = pipe.calibrate_density(codes, target=0.25)
        arms = {}
        arms["one_shot"] = pipe.train_one_shot(codes, labels)
        arms["iterative"] = pipe.fit_iterative(
            codes, labels, epochs=FIT_EPOCHS, margin=FIT_MARGIN)
        arms["adapted"] = _adapt_over(arms["iterative"], records[1:3], cfg)
        last_pipe = arms["one_shot"]
        aggs = {k: _evaluate(p, records[3:], cfg) for k, p in arms.items()}
        for k, agg in aggs.items():
            delays[k].append(agg["mean_delay_s"])
            rows.append({"name": f"online.p{pid}.{k}", "us_per_call": "",
                         "derived": _fmt(agg)})
        win = _improved(aggs["adapted"], aggs["one_shot"])
        wins += win
        rows.append({
            "name": f"online.p{pid}.win",
            "us_per_call": "",
            "derived": (f"improved={win}"
                        f";delay_s={aggs['one_shot']['mean_delay_s']:.2f}"
                        f"->{aggs['adapted']['mean_delay_s']:.2f}"
                        f";clean_acc={aggs['one_shot']['clean_accuracy']:.2f}"
                        f"->{aggs['adapted']['clean_accuracy']:.2f}"),
        })
    mean = {k: float(np.nanmean(v)) if np.isfinite(v).any() else float("nan")
            for k, v in delays.items()}
    rows.append({
        "name": "online.summary",
        "us_per_call": "",
        "derived": (f"patients_improved={wins}/{n_patients}"
                    f";mean_delay_s_one_shot={mean['one_shot']:.2f}"
                    f";mean_delay_s_iterative={mean['iterative']:.2f}"
                    f";mean_delay_s_adapted={mean['adapted']:.2f}"),
    })
    rows.extend(_fleet_rows(cfg, last_pipe, fleet_s))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
