"""Cold start to first decision: fresh JIT vs warm cache vs serialized AOT.

A restarted or autoscaled serving worker pays trace+compile for every
(bucket, tile) executable before its first decision — the tax
runtime/aot.py exists to kill.  This module measures that tax three ways on
ONE fleet geometry, all in the SAME process (the ±30% container-noise rule:
only same-process ratio rows are meaningful, absolute times are not):

  coldstart.S*.jit        fresh fleet, persistent compilation cache DISABLED
                          -> first push is a full trace + XLA compile
  coldstart.S*.warmcache  fresh fleet, persistent cache pointed at a deploy
                          artifact -> first push traces but the XLA compile
                          is a disk hit
  coldstart.S*.serialized fresh fleet warmed from the artifact's serialized
                          executables (``warmup(aot=...)`` timed INCLUSIVE)
                          -> no tracing, no XLA compile, no cache needed

plus the ``*.speedup`` ratio rows CI gates (check_fleet_regression.py
--coldstart), an ``artifact_compile`` row recording what ``save_aot`` cost,
and two correctness rows the gate requires to start with "ok":

  coldstart.bitexact      all three paths produced identical decisions
  coldstart.fallback      a key-tampered (stale) artifact loads as None and
                          the fleet falls back to JIT with identical
                          decisions

Scenario order is deliberate: the fresh-JIT baseline runs FIRST, before any
artifact exists, so nothing it compiles can be served from a cache.  Each
scenario starts from a freshly constructed fleet and ``jax.clear_caches()``,
so in-process tracing caches cannot leak between them either.

BENCH_TINY=1 (CI smoke) shrinks to a small geometry; the committed
BENCH_coldstart.json is a full-geometry run.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.runtime import aot as aot_mod
from repro.serve.fleet import StreamingFleet


def _config() -> tuple[HDCConfig, int]:
    if tiny():
        return HDCConfig(dim=256, segments=8, channels=16, window=64,
                         temporal_threshold=8), 8
    return HDCConfig(), 64


def _trained(cfg: HDCConfig) -> HDCPipeline:
    rng = np.random.default_rng(0)
    codes = jnp.asarray(
        rng.integers(0, cfg.codes, (1, 4 * cfg.window, cfg.channels), np.uint8))
    labels = np.asarray(rng.integers(0, 2, (1, 4), np.int32))
    labels[0, :2] = (0, 1)  # every class needs >= 1 example
    return HDCPipeline.init(jax.random.PRNGKey(42), cfg).train_one_shot(
        codes, jnp.asarray(labels))


def _decisions(out) -> list[tuple]:
    return [(d.frame_index, d.prediction, tuple(np.asarray(d.scores)))
            for per_session in out for d in per_session]


def run() -> list[dict]:
    cfg, s = _config()
    pipe = _trained(cfg)
    owners = ["p"] * s
    buckets = (cfg.window,)  # one executable: apples-to-apples across paths
    rng = np.random.default_rng(7)
    chunks = [rng.integers(0, cfg.codes, (cfg.window, cfg.channels), np.uint8)
              for _ in range(s)]

    def fresh_fleet() -> StreamingFleet:
        jax.clear_caches()
        return StreamingFleet({"p": pipe}, owners, buckets=buckets)

    rows: list[dict] = []
    tmp = tempfile.mkdtemp(prefix="bench_coldstart_")
    try:
        art_dir = os.path.join(tmp, "aot")

        # -- A: fresh JIT, no persistent cache anywhere -------------------
        with aot_mod.compilation_cache(None):
            fleet = fresh_fleet()
            t0 = time.perf_counter()
            out_jit = fleet.push(chunks)
            t_jit = time.perf_counter() - t0
        rows.append({
            "name": f"coldstart.S{s}.jit",
            "us_per_call": round(t_jit * 1e6, 1),
            "derived": "first push = trace + XLA compile + run "
                       "(persistent cache disabled)",
        })

        # -- build the deploy artifact (after A, so A saw cold everything)
        builder = fresh_fleet()
        t0 = time.perf_counter()
        builder.save_aot(art_dir)
        t_build = time.perf_counter() - t0
        rows.append({
            "name": f"coldstart.S{s}.artifact_compile",
            "us_per_call": round(t_build * 1e6, 1),
            "derived": "one-time `serve compile`: export + compile the "
                       "executable set into the artifact",
        })

        # -- B: warm persistent cache, plain JIT --------------------------
        with aot_mod.compilation_cache(os.path.join(art_dir,
                                                    aot_mod.XLA_CACHE_DIR)):
            fleet = fresh_fleet()
            t0 = time.perf_counter()
            out_cache = fleet.push(chunks)
            t_cache = time.perf_counter() - t0
        rows.append({
            "name": f"coldstart.S{s}.warmcache",
            "us_per_call": round(t_cache * 1e6, 1),
            "derived": "first push traces, XLA compile served from the "
                       "artifact's persistent cache",
        })

        # -- C: serialized executables (warmup timed inclusive) -----------
        with aot_mod.compilation_cache(None):
            fleet = fresh_fleet()
            t0 = time.perf_counter()
            art = aot_mod.load_artifact(art_dir)  # cache stays off
            stats = fleet.warmup(aot=art)
            out_aot = fleet.push(chunks)
            t_aot = time.perf_counter() - t0
        rows.append({
            "name": f"coldstart.S{s}.serialized",
            "us_per_call": round(t_aot * 1e6, 1),
            "derived": f"load artifact + warmup({stats['loaded']} loaded) + "
                       "first push: no tracing, no XLA compile",
        })

        for label, t in (("warmcache", t_cache), ("serialized", t_aot)):
            rows.append({
                "name": f"coldstart.S{s}.{label}.speedup",
                "us_per_call": "",
                "derived": f"{t_jit / t:.2f}x faster to first decision than "
                           "process-fresh trace+compile (same process)",
            })

        # -- correctness rows the CI gate requires ------------------------
        ok = _decisions(out_jit) == _decisions(out_cache) == _decisions(out_aot)
        rows.append({
            "name": "coldstart.bitexact",
            "us_per_call": "",
            "derived": ("ok all three cold-start paths produced identical "
                        "decisions" if ok else
                        "MISMATCH between cold-start paths"),
        })

        # tamper the artifact key -> load must refuse, fleet must fall back
        stale_dir = os.path.join(tmp, "aot_stale")
        shutil.copytree(art_dir, stale_dir)
        mpath = os.path.join(stale_dir, aot_mod.MANIFEST)
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["key"]["jax"] = "0.0.0-stale"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        import warnings as _warnings

        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore")
            stale = aot_mod.load_artifact(stale_dir)
        with aot_mod.compilation_cache(None):
            fleet = fresh_fleet()
            fleet.warmup(aot=stale)  # stale is None: pre-compiles via JIT
            out_stale = fleet.push(chunks)
        fb_ok = stale is None and _decisions(out_stale) == _decisions(out_jit)
        rows.append({
            "name": "coldstart.fallback",
            "us_per_call": "",
            "derived": ("ok stale artifact refused (load_artifact -> None), "
                        "JIT fallback decisions identical" if fb_ok else
                        "STALE-ARTIFACT FALLBACK BROKEN"),
        })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
