"""Paper Fig. 5: energy & area across dense / sparse-naive / +CompIM /
+no-thinning (ours), with the headline ratios.

Derived values = modeled totals + ratios vs the paper's claims
(1.72-1.73x E, 2.20x A vs naive; 7.50x E, 3.24x A vs dense)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hwmodel
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg


def run() -> list[dict]:
    # variant="sparse_naive" precomputes the packed IM tables, which the
    # eager hwmodel sweep reads repeatedly (params are key-deterministic
    # and identical across sparse variants)
    cfg = HDCConfig(variant="sparse_naive", spatial_threshold=1)
    params = HDCPipeline.init(jax.random.PRNGKey(42), cfg).params
    dparams = HDCPipeline.init(jax.random.PRNGKey(7),
                               HDCConfig(variant="dense")).params
    codes = jnp.asarray(ieeg.make_patient(11, n_seizures=1).records[0].codes[:2048])
    es, asc = hwmodel.calibration_factors(params, codes, cfg)
    reports = {v: hwmodel.report(v, dparams if v == "dense" else params,
                                 codes, cfg, e_scale=es, a_scale=asc)
               for v in hwmodel.VARIANTS}
    rows = []
    for v, r in reports.items():
        rows.append({"name": f"fig5.{v}",
                     "us_per_call": "",
                     "derived": (f"E={r['energy_total_nj']:.2f}nJ"
                                 f";A={r['area_total_mm2']:.4f}mm2")})
    sn, so, dn = (reports[k] for k in ("sparse_naive", "sparse_opt", "dense"))
    rows.append({"name": "fig5.ratio_vs_naive",
                 "us_per_call": "",
                 "derived": (f"E={sn['energy_total_nj']/so['energy_total_nj']:.2f}x"
                             f";A={sn['area_total_mm2']/so['area_total_mm2']:.2f}x"
                             " (paper: 1.72x;2.20x)")})
    rows.append({"name": "fig5.ratio_vs_dense",
                 "us_per_call": "",
                 "derived": (f"E={dn['energy_total_nj']/so['energy_total_nj']:.2f}x"
                             f";A={dn['area_total_mm2']/so['area_total_mm2']:.2f}x"
                             " (paper: 7.50x;3.24x)")})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
