"""Roofline aggregation: read artifacts/dryrun/*.json -> the §Roofline table.

Per (arch x shape) on the single-pod mesh: the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line suggestion for
the dominant term.  Also emits the multi-pod pass/fail summary."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

SUGGESTIONS = {
    "compute": ("shed non-useful FLOPs: GQA-KV TP replication, remat policy, "
                "MoE capacity slack"),
    "memory": ("raise arithmetic intensity: larger per-device batch, fuse "
               "attention chunks, bf16 intermediates"),
    "collective": ("reshard to cut gathered bytes: FSDP prefetch granularity, "
                   "MoE all-to-all instead of gather, overlap with compute"),
}


def load_records(mesh: str = "single") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if r.get("status") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | "
                f"{r['reason'][:60]} |")
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | "
                f"{r.get('error', '')[:60]} |")
    if "roofline" not in r:   # hdc serve cell: derive terms inline
        from repro.runtime.roofline import HBM_BW, PEAK_FLOPS, collective_seconds
        c = r.get("cost", {})
        t = {"compute_s": c.get("flops", 0) / PEAK_FLOPS,
             "memory_s": c.get("bytes accessed", 0) / HBM_BW,
             "collective_s": collective_seconds(r.get("collectives", {})),
             "useful_flops_fraction": float("nan")}
        t["bottleneck"] = max((("compute", t["compute_s"]),
                               ("memory", t["memory_s"]),
                               ("collective", t["collective_s"])),
                              key=lambda kv: kv[1])[0]
    else:
        t = r["roofline"]
    return ("| {arch} | {shape} | {c:.3f} | {m:.3f} | {k:.3f} | {b} | "
            "{u:.2f} | {s} |".format(
                arch=r["arch"], shape=r["shape"], c=t["compute_s"],
                m=t["memory_s"], k=t["collective_s"], b=t["bottleneck"],
                u=t["useful_flops_fraction"],
                s=SUGGESTIONS.get(t["bottleneck"], "")[:60]))


def run() -> list[dict]:
    rows = []
    for r in load_records("single"):
        if r.get("status") == "ok" and "roofline" in r:
            t = r["roofline"]
            tag = f".{r['tag']}" if r.get("tag") else ""
            rows.append({
                "name": f"roofline.{r['arch']}.{r['shape']}{tag}",
                "us_per_call": f"{t['step_time_bound_s'] * 1e6:.0f}",
                "derived": (f"bottleneck={t['bottleneck']}"
                            f";useful={t['useful_flops_fraction']:.2f}"),
            })
        elif r.get("status") == "ok":   # hdc serve cell (terms derived inline)
            c = r.get("cost", {})
            rows.append({
                "name": f"roofline.{r['arch']}.{r['shape']}",
                "us_per_call": f"{c.get('bytes accessed', 0) / 819e9 * 1e6:.0f}",
                "derived": "bottleneck=memory;collectives=0",
            })
        else:
            rows.append({"name": f"roofline.{r['arch']}.{r['shape']}",
                         "us_per_call": "",
                         "derived": r.get("status")})
    multi = load_records("multi")
    n_ok = sum(r.get("status") == "ok" for r in multi)
    n_skip = sum(r.get("status") == "skipped" for r in multi)
    rows.append({"name": "roofline.multipod_summary",
                 "us_per_call": "",
                 "derived": f"ok={n_ok};skipped={n_skip};total={len(multi)}"})
    return rows


def markdown_table(mesh: str = "single") -> str:
    head = ("| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | useful_flops | next lever |\n"
            "|---|---|---|---|---|---|---|---|")
    return "\n".join([head] + [fmt_row(r) for r in load_records(mesh)])


if __name__ == "__main__":
    print(markdown_table("single"))
    print()
    from benchmarks.common import emit
    emit(run())
