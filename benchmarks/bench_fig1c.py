"""Paper Fig. 1c: energy & area breakdown of the NAIVE sparse HDC system.

Reproduced with the switching-activity cost model (core/hwmodel.py) on
synthetic patient-11 LBP streams.  Derived value = energy share of
binding + one-hot decoder (paper: 51.3%)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hwmodel
from repro.core.pipeline import HDCConfig, HDCPipeline
from repro.data import ieeg


def run() -> list[dict]:
    # variant="sparse_naive" precomputes the packed IM tables, which the
    # eager hwmodel sweep reads repeatedly (params are key-deterministic
    # and identical across sparse variants)
    cfg = HDCConfig(variant="sparse_naive", spatial_threshold=1)
    params = HDCPipeline.init(jax.random.PRNGKey(42), cfg).params
    codes = jnp.asarray(ieeg.make_patient(11, n_seizures=1).records[0].codes[:2048])
    es, asc = hwmodel.calibration_factors(params, codes, cfg)
    r = hwmodel.report("sparse_naive", params, codes, cfg, e_scale=es, a_scale=asc)
    rows = []
    for mod in r["energy_nj"]:
        rows.append({
            "name": f"fig1c.{mod}",
            "us_per_call": "",
            "derived": (f"E%={100 * r['energy_breakdown'][mod]:.1f}"
                        f";A%={100 * r['area_breakdown'].get(mod, 0):.1f}"),
        })
    bind_dec = r["energy_breakdown"]["binding"] + r["energy_breakdown"]["decoder"]
    rows.append({"name": "fig1c.binding_plus_decoder_energy_share",
                 "us_per_call": "",
                 "derived": f"{100 * bind_dec:.1f}% (paper: 51.3%)"})
    rows.append({"name": "fig1c.naive_total",
                 "us_per_call": "",
                 "derived": (f"E={r['energy_total_nj']:.1f}nJ"
                             f";A={r['area_total_mm2']:.4f}mm2")})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
