"""Benchmark harness: one module per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows.

  fig1c       naive-sparse energy/area breakdown       (paper Fig. 1c)
  fig4        delay/accuracy vs max HV density          (paper Fig. 4)
  fig5        4-variant energy/area + headline ratios   (paper Fig. 5)
  table1      SotA comparison                           (paper Table I)
  throughput  HDC pipeline throughput + traffic model   (TPU-side perf)
  roofline    aggregated dry-run roofline terms          (framework)
"""

from __future__ import annotations

import sys

from benchmarks.common import emit


def main() -> None:
    mods = sys.argv[1:] or ["fig1c", "fig4", "fig5", "table1", "throughput",
                            "roofline"]
    print("name,us_per_call,derived")
    for mod in mods:
        try:
            name = f"benchmarks.bench_{mod}" if mod != "roofline" else "benchmarks.roofline"
            module = __import__(name, fromlist=["run"])
            emit(module.run())
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{mod}.ERROR,,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
