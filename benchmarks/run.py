"""Benchmark harness: one module per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows to stdout and writes a
machine-readable ``BENCH_<module>.json`` per module to ``--out-dir`` (CI
uploads these as artifacts, so the perf trajectory accumulates).

  fig1c       naive-sparse energy/area breakdown       (paper Fig. 1c)
  fig4        delay/accuracy vs max HV density          (paper Fig. 4)
  fig5        4-variant energy/area + headline ratios   (paper Fig. 5)
  table1      SotA comparison                           (paper Table I)
  throughput  HDC pipeline throughput + traffic model   (TPU-side perf)
  fleet       StreamingFleet vs looped-session serving  (framework)
  online      one-shot vs iterative/online retraining   (framework)
  reliability BER degradation curves + AM ECC tradeoff  (framework)
  channelfault electrode faults: quarantine vs unmasked  (framework)
  coldstart   fresh-JIT vs warm-cache vs serialized AOT (framework)
  churn       elastic fleet under Poisson session churn (framework)
  roofline    aggregated dry-run roofline terms          (framework)

A module that raises still prints a ``<mod>.ERROR`` CSV row (so partial runs
stay greppable) but the error is ALSO recorded in the module's JSON and the
process exits nonzero — crashes do not masquerade as results.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

from benchmarks.common import emit, write_bench_json

DEFAULT_MODULES = ["fig1c", "fig4", "fig5", "table1", "throughput", "fleet",
                   "online", "reliability", "channelfault", "coldstart",
                   "churn", "roofline"]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modules", nargs="*", default=None,
                    help=f"benchmark modules to run (default: {' '.join(DEFAULT_MODULES)})")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the BENCH_<module>.json artifacts")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke mode: modules shrink to tiny configs (sets BENCH_TINY=1)")
    args = ap.parse_args(argv)
    if args.tiny:
        os.environ["BENCH_TINY"] = "1"
    mods = args.modules or DEFAULT_MODULES
    os.makedirs(args.out_dir, exist_ok=True)

    failed: list[str] = []
    print("name,us_per_call,derived")
    for mod in mods:
        name = "benchmarks.roofline" if mod == "roofline" else f"benchmarks.bench_{mod}"
        try:
            module = __import__(name, fromlist=["run"])
            rows = module.run()
            emit(rows)
            write_bench_json(args.out_dir, mod, rows)
        except Exception as e:  # noqa: BLE001 - recorded, then exit nonzero
            print(f"{mod}.ERROR,,{type(e).__name__}: {e}")
            write_bench_json(args.out_dir, mod, [],
                             error=f"{type(e).__name__}: {e}\n{traceback.format_exc()}")
            failed.append(mod)
    if failed:
        print(f"benchmark modules failed: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
